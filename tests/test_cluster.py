"""Sharded multi-process VStore: wire-form round trips, scatter-gather
bit-identity vs the single-process path (incl. a hypothesis property over
query mixes), cluster-wide stats accounting, budget-lease coordination,
and generation-checked worker restart mid-query."""

import threading
import time

import numpy as np
import pytest

from _hyp_compat import given, settings, st
from repro.analytics.query import QueryResult, run_query
from repro.analytics.scene import generate_segment
from repro.cluster import (ClusterIngest, ShardRouter, config_from_wire,
                           config_to_wire, erosion_plan_from_wire,
                           erosion_plan_to_wire, merge_results, pack,
                           stable_shard, unpack)
from repro.core.knobs import IngestSpec
from repro.launch.vserve import demo_config
from repro.serving import QueryRequest
from repro.videostore import VideoStore

SPEC = IngestSpec()
STREAMS = ["jackson", "tucson"]  # crc32-hash to shards 1 and 0
SEGS = [0, 1, 2]


@pytest.fixture(scope="module")
def cfg():
    return demo_config(accuracies=(0.8, 0.9))


@pytest.fixture(scope="module")
def ref(cfg, tmp_path_factory):
    """Single-process reference store with the identical content."""
    vs = VideoStore(str(tmp_path_factory.mktemp("ref")), SPEC)
    vs.set_formats(cfg.storage_formats())
    for s in STREAMS:
        for g in SEGS:
            vs.ingest_segment(s, g, generate_segment(s, g, SPEC)[0])
    return vs


@pytest.fixture(scope="module")
def cluster(cfg, tmp_path_factory):
    """A 2-shard cluster over the same content (per-shard worker
    processes, spawn start-method)."""
    root = str(tmp_path_factory.mktemp("cluster"))
    router = ShardRouter(root, cfg, 2, spec=SPEC,
                         opts={"workers": 1}).start()
    for s in STREAMS:
        for g in SEGS:
            router.ingest(s, g, generate_segment(s, g, SPEC)[0])
    yield router
    router.close()


# ---------------------------------------------------------------------------
# wire forms (no processes involved)
# ---------------------------------------------------------------------------

def test_wire_ndarray_roundtrip():
    arr = np.arange(24, dtype=np.uint8).reshape(2, 3, 4)
    out = unpack(pack({"x": arr, "n": 3}))
    assert np.array_equal(out["x"], arr) and out["x"].dtype == np.uint8
    assert out["n"] == 3
    out["x"][0, 0, 0] = 99  # decoded arrays must be writable copies


def test_wire_config_roundtrip(cfg):
    back = config_from_wire(config_to_wire(cfg))
    assert back.storage_formats() == cfg.storage_formats()
    for p in cfg.plans:
        op, acc = p.consumer.op, p.consumer.target
        assert back.consumption_format(op, acc) == p.cf
        assert back.subscription(back.consumption_format(op, acc)) == \
            cfg.subscription(cfg.consumption_format(op, acc))


def test_wire_query_result_roundtrip(ref, cfg):
    res = run_query(ref, cfg, "A", "jackson", SEGS, 0.8)
    back = QueryResult.from_wire(unpack(pack(res.to_wire())))
    assert back.items == res.items
    assert back.video_seconds == res.video_seconds
    assert [s.op for s in back.stages] == [s.op for s in res.stages]
    assert all(a.cf == b.cf and a.sf_id == b.sf_id and a.frames == b.frames
               for a, b in zip(back.stages, res.stages))


def test_wire_query_request_roundtrip():
    req = QueryRequest("A", "jackson", [0, 2], 0.9)
    back = QueryRequest.from_wire(unpack(pack(req.to_wire())))
    assert back == req


def test_wire_erosion_plan_roundtrip():
    from repro.core.erosion import ErosionPlan
    plan = ErosionPlan(k=2.0, ages=[1, 3], fractions=[{0: 0.25}, {1: 0.5}],
                       overall_speed=[1.0, 0.8], daily_bytes=[10.0, 5.0],
                       total_bytes=30.0, feasible=True)
    back = erosion_plan_from_wire(unpack(pack(erosion_plan_to_wire(plan))))
    assert back == plan


def test_stable_shard_is_stable():
    assert stable_shard("jackson", 2) == 1
    assert stable_shard("tucson", 2) == 0
    assert all(stable_shard(s, 4) == stable_shard(s, 4) for s in STREAMS)


# ---------------------------------------------------------------------------
# cross-process identity
# ---------------------------------------------------------------------------

def test_single_stream_bit_identical(cluster, ref, cfg):
    for q, s, acc in (("A", "jackson", 0.8), ("B", "tucson", 0.9)):
        got = cluster.query(q, s, SEGS, acc)
        want = run_query(ref, cfg, q, s, SEGS, acc)
        assert got.items == want.items
        assert got.video_seconds == want.video_seconds


def test_multi_stream_scatter_gather(cluster, ref, cfg):
    got = cluster.query("A", STREAMS, SEGS, 0.8)
    want = merge_results(
        {s: run_query(ref, cfg, "A", s, SEGS, 0.8) for s in STREAMS})
    assert got.items == want.items
    assert got.video_seconds == want.video_seconds
    # every item carries its stream tag
    assert {it[0] for it in got.items} <= set(STREAMS)


@settings(max_examples=8, deadline=None)
@given(q=st.sampled_from(["A", "B"]),
       streams=st.lists(st.sampled_from(STREAMS), min_size=1, max_size=2,
                        unique=True),
       segs=st.lists(st.sampled_from(SEGS), min_size=1, max_size=3,
                     unique=True),
       acc=st.sampled_from([0.8, 0.9]))
def test_sharded_identical_property(cluster, ref, cfg, q, streams, segs,
                                    acc):
    segs = sorted(segs)
    got = cluster.query(q, streams if len(streams) > 1 else streams[0],
                        segs, acc)
    if len(streams) > 1:
        want = merge_results(
            {s: run_query(ref, cfg, q, s, segs, acc) for s in streams})
    else:
        want = run_query(ref, cfg, q, streams[0], segs, acc)
    assert got.items == want.items


def test_query_many_multi_stream_no_pool_deadlock(cluster, ref, cfg):
    """More multi-stream submissions than router pool threads: sub-queries
    must be flattened into the pool, never nested (an outer task blocking
    on inner tasks queued behind other outer tasks would hang forever)."""
    n = cluster._pool._max_workers + 2
    subs = [("A", STREAMS, [0, 1], 0.8)] * n
    results = cluster.query_many(subs)
    want = merge_results(
        {s: run_query(ref, cfg, "A", s, [0, 1], 0.8) for s in STREAMS})
    assert all(r.items == want.items for r in results)


def test_query_many_order_and_stats_accounting(cluster, ref, cfg):
    subs = [("A", "jackson", SEGS, 0.8), ("B", "tucson", SEGS, 0.8),
            ("A", "tucson", SEGS, 0.9), ("B", "jackson", SEGS, 0.9)]
    before = cluster.stats()
    results = cluster.query_many(subs)
    after = cluster.stats()
    for res, (q, s, sg, acc) in zip(results, subs):
        assert res.items == run_query(ref, cfg, q, s, sg, acc).items
    # stable accounting: every submission lands in exactly one shard's
    # completed counter, and the rollup sums them
    assert after["completed"] - before["completed"] == len(subs)
    assert after["completed"] == sum(s["completed"]
                                     for s in after["shards"])
    vsec = sum(r.video_seconds for r in results)
    assert after["video_seconds"] - before["video_seconds"] == \
        pytest.approx(vsec)
    assert after["failed"] == 0


# ---------------------------------------------------------------------------
# crash / restart
# ---------------------------------------------------------------------------

def test_worker_restart_mid_query(cluster, ref, cfg):
    want = run_query(ref, cfg, "A", "jackson", SEGS, 0.8)
    host = cluster.host_of("jackson")
    gen0, sid0, restarts0 = host.generation, host.store_id, host.restarts
    out = {}
    t = threading.Thread(target=lambda: out.setdefault(
        "res", cluster.query("A", "jackson", SEGS, 0.8)))
    t.start()
    time.sleep(0.02)
    host.kill()  # SIGKILL mid-flight; router must reattach and retry
    t.join(timeout=240)
    assert not t.is_alive()
    assert out["res"].items == want.items
    assert host.restarts == restarts0 + 1
    assert host.generation == gen0 + 1
    assert host.store_id == sid0
    # the restarted worker serves the same durable store
    st = cluster.stats()
    assert st["shards"][host.idx]["store_id"] == sid0
    assert st["shards"][host.idx]["generation"] == gen0 + 1
    again = cluster.query("B", "jackson", SEGS, 0.9)
    assert again.items == run_query(ref, cfg, "B", "jackson", SEGS,
                                    0.9).items


def test_readonly_attach_identity(cluster, cfg):
    host = cluster.hosts[0]
    ro = VideoStore(host.shard_dir, readonly=True)
    # the identity the router's reattach path checks, via the same API
    assert ro.store_id == host.store_id
    assert sorted(ro.formats) == sorted(cfg.storage_formats())
    with pytest.raises(RuntimeError):
        ro.set_formats(ro.formats)
    with pytest.raises(RuntimeError):
        ro.backend.put("k", b"v")
    with pytest.raises(RuntimeError):
        ro.backend.delete("k")
    # reads work (another process owns the store; we only observe)
    keys = ro.backend.keys()
    assert keys and isinstance(ro.backend.get(keys[0]), bytes)


# ---------------------------------------------------------------------------
# cluster ingest coordination (budget leases over the wire)
# ---------------------------------------------------------------------------

def test_cluster_ingest_budget_and_erosion(cfg, tmp_path_factory):
    from repro.launch.vserve import demo_erosion_plan
    plan = demo_erosion_plan(cfg, SPEC, 2)
    opts = {"workers": 1, "ingest": True, "budget_x": 0.05,
            "erosion_plan": erosion_plan_to_wire(plan),
            "node_ids": [cfg.node_id(i) for i in range(len(cfg.nodes))]}
    root = str(tmp_path_factory.mktemp("cingest"))
    with ShardRouter(root, cfg, 2, spec=SPEC, opts=opts) as router:
        coord = ClusterIngest(router, budget_x=0.05)
        for s in STREAMS:
            for g in (0, 1):
                coord.ingest(s, g, generate_segment(s, g, SPEC)[0])
        st = coord.stats()
        # a budget below full materialization leaves debt, rolled up
        # per-format across both shards
        assert st["pending"] > 0 and st["debt_s"] > 0
        assert set(st["formats"]) and all(
            v["pending"] >= 0 for v in st["formats"].values())
        # mid-ingest query still answers over the fallback chain
        mid = router.query("A", "jackson", [0, 1], 0.8)
        # raise globally through the coordinator's leases -> debt drains
        coord.set_budget_x(None)
        assert all(g is None for g in coord.grants)
        coord.drain()
        assert coord.stats()["debt_s"] == 0
        post = router.query("A", "jackson", [0, 1], 0.8)
        assert post.items == mid.items  # fallback reads were bit-exact
        # cluster-wide erosion: day clock moves in lockstep, bytes roll up
        rep = coord.erode_advance(2)
        assert rep["day"] == 2
        assert rep["segments"] > 0 and rep["bytes"] > 0
        assert rep["per_format"]
        eroded = router.query("A", "jackson", [0, 1], 0.8)
        assert eroded.items == mid.items  # reconstruction serves reads


def test_rebalance_directs_budget_at_backlog(cfg, tmp_path_factory):
    opts = {"workers": 1, "ingest": True, "budget_x": 0.05}
    root = str(tmp_path_factory.mktemp("rebalance"))
    with ShardRouter(root, cfg, 2, spec=SPEC, opts=opts) as router:
        coord = ClusterIngest(router, budget_x=0.05)
        for s in STREAMS:  # both shards see equal arrivals
            for g in (0, 1):
                coord.ingest(s, g, generate_segment(s, g, SPEC)[0])
        # clear one shard's backlog out-of-band; the other keeps its debt
        drained = router.shard_of("tucson")
        backlogged = 1 - drained
        router.hosts[drained].call_retry("drain")
        grants = coord.rebalance()
        # the whole observed debt sits on one shard: it is granted ~the
        # cluster's full rate (2x uniform here), the drained shard ~0 —
        # conserving sum(rate_i * arrivals_i) ~= global * total_arrivals
        assert grants[backlogged] == pytest.approx(0.10, rel=1e-6)
        assert grants[drained] == pytest.approx(0.0, abs=1e-9)
        total = sum(g * 8.0 for g in grants)  # 8 video-seconds per shard
        assert total == pytest.approx(0.05 * 16.0, rel=1e-6)
        # crash the backlogged worker: reattach must re-apply the
        # coordinator's CURRENT grant (a respawn reverts to the spawn-time
        # budget) and re-adopt the lost transcode queue from the store
        router.hosts[backlogged].kill()
        st = coord.stats()  # call_retry reattaches; on_reattach re-grants
        assert router.hosts[backlogged].generation == 1
        shard_ing = st["per_shard"][backlogged]
        assert shard_ing["budget_x"] == pytest.approx(grants[backlogged])
        assert shard_ing["debt_s"] > 0  # adopt_missing restored backlog
        coord.set_budget_x(None)
        coord.drain()
        assert coord.stats()["debt_s"] == 0


def test_index_survives_worker_sigkill_mid_backfill(tmp_path_factory):
    """Shard-local semantic indexes are crash-safe at the IndexStore's ack
    point (flush): SIGKILL a worker while sketch backfill is still
    draining, reattach, and every sketch acked before the kill must
    reload intact (no torn records); the lost tail is rebuilt by
    ``adopt_missing`` and pushdown answers stay bit-identical."""
    import msgpack

    from repro.index import SemanticIndex, SketchRecord
    from repro.index.store import IndexStore

    cfg = demo_config(index_ops=("diff", "motion"))
    opts = {"workers": 1, "ingest": True, "budget_x": 0.05}
    root = str(tmp_path_factory.mktemp("cidx"))
    with ShardRouter(root, cfg, 2, spec=SPEC, opts=opts) as router:
        coord = ClusterIngest(router, budget_x=0.05)
        for s in STREAMS:
            for g in SEGS:
                coord.ingest(s, g, generate_segment(s, g, SPEC)[0])
        want = router.query("A", "jackson", SEGS, 0.8)

        host = router.host_of("jackson")
        gen0 = host.generation
        # pump a few tasks synchronously (op_pump flushes store AND index:
        # that flush is the ack), then snapshot what is ACKED — a readonly
        # load sees only the flushed prefix, exactly like a restart will.
        # The tight 0.05x budget has no credit left, so lift this shard's
        # lease for the pump and clamp it back before the kill.
        host.call_retry("set_budget", budget_x=None)
        pumped = host.call_retry("pump", max_tasks=8)
        host.call_retry("set_budget", budget_x=0.05)
        assert pumped > 0
        idx_dir = f"{host.shard_dir}/index"
        snap = IndexStore(idx_dir, readonly=True)
        acked = {k: snap.get(k) for k in snap.keys()}
        assert acked  # sketches ride right behind their source transcode
        host.kill()  # SIGKILL with sketch backfill still pending

        # reattach + finish the backfill: the restarted worker re-adopts
        # missing sketches from the durable store
        coord.set_budget_x(None)
        coord.drain()
        assert host.generation == gen0 + 1
        st = router.stats()
        n_total = len(STREAMS) * len(SEGS) * len(cfg.index_ops)
        assert st["index_sketches"] == n_total

        # every acked sketch survived the kill and parses cleanly
        after = IndexStore(idx_dir, readonly=True)
        for k, blob in acked.items():
            assert after.get(k) == blob, k
        for k in after.keys():
            rec = SketchRecord.from_wire(
                msgpack.unpackb(after.get(k), strict_map_key=False))
            assert rec.op in cfg.index_ops and rec.n_buckets > 0

        # the reloaded index serves pushdown with bit-identical answers
        again = router.query("A", "jackson", SEGS, 0.8)
        assert again.items == want.items
        # and the shard process really reads the same records the test does
        ro = SemanticIndex(idx_dir, SPEC, cfg, readonly=True)
        assert ro.has_sketch("jackson", 0, "diff")


# ---------------------------------------------------------------------------
# distributed tracing
# ---------------------------------------------------------------------------

def test_cluster_trace_propagation_and_restart(ref, cfg, tmp_path_factory):
    """Trace context crosses the wire: shard-side spans re-parent under the
    router's query span, cover every data-path stage on every shard, and a
    SIGKILL'd-then-reattached worker cannot corrupt the merged timeline."""
    import json

    from repro.obs import trace as obstrace

    root = str(tmp_path_factory.mktemp("traced"))
    obstrace.enable(True)
    obstrace.TRACER.clear()
    try:
        with ShardRouter(root, cfg, 2, spec=SPEC,
                         opts={"workers": 1, "trace": True}) as router:
            for s in STREAMS:
                for g in SEGS:
                    router.ingest(s, g, generate_segment(s, g, SPEC)[0])
            results = {}
            for s in STREAMS:  # one query per shard: spans on every shard
                results[s] = router.query("A", s, SEGS, 0.8)
            for s in STREAMS:  # tracing observes, never perturbs
                want = run_query(ref, cfg, "A", s, SEGS, 0.8)
                assert results[s].items == want.items

            spans = obstrace.TRACER.spans()
            by_id = {sp.span_id: sp for sp in spans}
            shard_pids = {h.idx + 1 for h in router.hosts}
            assert shard_pids <= {sp.pid for sp in spans}
            for pid in shard_pids:  # full data path visible per shard
                names = {sp.name for sp in spans if sp.pid == pid}
                assert {"query", "retrieve", "codec.decode", "convert",
                        "detect"} <= names
            for sp in spans:  # merged timeline: every parent resolves
                assert sp.parent_id == 0 or sp.parent_id in by_id
            shard_queries = [sp for sp in spans
                             if sp.name == "query" and sp.pid in shard_pids]
            assert shard_queries
            for sq in shard_queries:  # shard query -> rpc:query -> root
                rpc = by_id[sq.parent_id]
                assert rpc.name == "rpc:query"
                assert rpc.pid not in shard_pids
                top = by_id[rpc.parent_id]
                assert top.name == "query"
                assert top.trace_id == sq.trace_id
                assert sq.t0 >= top.t0 - 0.05  # clock-offset rebased

            # SIGKILL mid-query: retried query completes identically and
            # the respawned worker's spans merge without dangling parents
            n_before = len(spans)
            host = router.host_of("jackson")
            out = {}
            t = threading.Thread(target=lambda: out.setdefault(
                "res", router.query("A", "jackson", SEGS, 0.8)))
            t.start()
            time.sleep(0.02)
            host.kill()
            t.join(timeout=240)
            assert not t.is_alive()
            assert out["res"].items == results["jackson"].items
            router.harvest_spans()  # ingest-time spans still on workers
            spans = obstrace.TRACER.spans()
            assert len(spans) > n_before
            by_id = {sp.span_id: sp for sp in spans}
            for sp in spans:
                assert sp.parent_id == 0 or sp.parent_id in by_id

            path = f"{root}/trace.json"
            n = obstrace.export_trace(path)
            assert n == len(spans)
            with open(path) as f:
                doc = json.load(f)
            assert any(e["ph"] == "X" for e in doc["traceEvents"])
    finally:
        obstrace.enable(False)
        obstrace.TRACER.clear()
