"""Root conftest: opt-in runtime concurrency checking.

With ``REPRO_ANALYSIS=1`` in the environment, the mini-TSan from
``repro.analysis.runtime`` is installed *at import time* — before pytest
collects anything — so every ``threading.Lock``/``RLock`` the suite
creates is traced.  At session end the observed acquisition graph is
validated (cycles, blocking-under-lock, inversions of the static lock
order from ``repro.analysis.locks``) and any violation fails the run.

Shard worker processes install the checker themselves when they see the
env var (see ``repro.cluster.worker.shard_worker_main``); their lock
orders are validated in-process since edges can't cross the exit.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")

try:
    import repro.analysis.runtime as _runtime
except ImportError:
    sys.path.insert(0, _SRC)
    import repro.analysis.runtime as _runtime

_runtime.install_from_env()


def pytest_sessionfinish(session, exitstatus):
    if not _runtime.installed():
        return
    from repro.analysis.core import load_tree
    from repro.analysis import locks

    lock_an = locks.analyze(load_tree(os.path.join(_SRC, "repro")))
    violations = _runtime.check(static_sites=lock_an.sites,
                                static_edges=set(lock_an.edges))
    if violations:
        tr = session.config.pluginmanager.get_plugin("terminalreporter")
        lines = ["REPRO_ANALYSIS: runtime concurrency violations:"]
        lines += [f"  - {v}" for v in violations]
        if tr is not None:
            tr.write_sep("=", "repro.analysis runtime checker")
            for ln in lines:
                tr.write_line(ln)
        else:  # pragma: no cover - no terminal reporter registered
            print("\n".join(lines), file=sys.stderr)
        session.exitstatus = 1
