"""Regression guard for the benchmark JSON artifacts.

``benchmarks.run --check BASELINE.json`` compares the rows of the current
run against a committed baseline so the perf trajectory actually gates in
CI.  Rules, designed to be robust across machines of different speeds:

* any row whose ``derived`` field records an ``ERROR=`` fails the check;
* *relative* metrics (``speedup``, ``hit_rate`` — same-host ratios of two
  measurements, which transfer between machines) must reach at least
  ``factor`` x their baseline value.  Absolute numbers — ``us_per_call``
  and the ``*_x`` x-realtime speeds — are NOT compared: they scale with
  host speed and would fail spuriously on a slower CI runner;
* boolean metrics that were ``True`` in the baseline (``identical``,
  ``fewer_calls``, ...) must still be ``True`` — correctness claims never
  get a tolerance, and one going missing is itself a violation.

Rows are matched by bench name plus their identity parameters (the
knob-valued ``k=v`` pairs such as ``mode=sparse`` or ``query=B``); only
bench names present in the current run are checked, so ``--only`` subsets
work.
"""

from __future__ import annotations

# k=v keys that identify a row (workload knobs), as opposed to measurements
ID_KEYS = {
    "mode", "config", "query", "op", "acc", "kint", "n", "step", "q",
    "res", "segments", "arch", "shape", "budget_frac", "sampling",
    "streams", "shards", "dup", "active", "pace",
}
# measured same-host ratio metrics guarded with a factor (absolute *_x
# x-realtime speeds are deliberately excluded — host-speed dependent)
GUARD_KEYS = {"speedup", "hit_rate", "call_reduction", "decode_reduction"}
# boolean claims guarded exactly
BOOL_VALUES = {"True", "False"}
# boolean claims that encode an absolute-speed threshold (e.g. "golden
# encode >= 1x realtime") — true on any reasonable host but a property of
# the machine, not the code, so excluded from the exact gate for the same
# reason the *_x speeds are.  "scales" (cluster_scaling's >= 1.5x process
# speedup) is host-capacity-dependent the same way: overcommitted CI
# sandboxes grant two busy processes well under 2 cores of real time.
# "scales_to_host" normalizes by a measured spin-loop capacity, but that
# calibration is systematically optimistic (no memory/IPC contention) and
# sampled at a different moment than the timed windows, so it stays
# informative rather than exactly gated; the factor-gated `speedup` ratio
# is the enforceable scaling regression guard.
HOST_SPEED_BOOL_KEYS = {"golden_realtime", "scales", "scales_to_host",
                        "low_overhead", "realtime_1_5x",
                        # ingest_soak's debt-stationarity claim holds
                        # whenever the calibrated budget grants enough
                        # real CPU time — a property of the host's load,
                        # not of the scheduler code
                        "stationary"}
# absolute floors for specific (bench, metric) pairs, applied on top of
# the relative factor: cluster_scaling's speedup is host-capacity-capped
# (so its factor floor lands below 1.0), but a cluster that fails to beat
# one process AT ALL is a code regression, not host noise — the most
# overcommitted sandbox observed still measures >= 1.2
ABS_MIN = {("cluster_scaling", "speedup"): 1.1,
           # the acceptance claim: fused detects <= 0.5x the per-query
           # count — detect-call counts are deterministic enough across
           # hosts that the 2x reduction itself is the gate
           ("cross_query_batching", "call_reduction"): 2.0,
           # the acceptance claim for semantic-index pushdown: >= 5x
           # fewer stage-0 decoded segments — segment counts are exact
           # (sketch activations are deterministic), so the floor gates
           # the reduction itself, not a host-scaled fraction of it
           ("predicate_pushdown", "decode_reduction"): 5.0}


def parse_derived(derived: str) -> dict[str, str]:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def _row_key(row: dict) -> tuple:
    kv = parse_derived(row.get("derived", ""))
    ident = tuple(sorted((k, v) for k, v in kv.items() if k in ID_KEYS))
    return (row["name"], ident)


def _guarded(kv: dict) -> dict[str, float]:
    out = {}
    for k, v in kv.items():
        if k in GUARD_KEYS:
            try:
                out[k] = float(v)
            except ValueError:
                pass
    return out


def _superset_match(current: dict, name: str, base_ident: dict):
    """Additive-key tolerance: a bench that grew new identity knobs since
    the baseline was committed still matches — any current row of the same
    name whose ident *extends* the baseline's (agrees on every baseline
    key) counts.  Multiple extending rows merge with the duplicate-row
    semantics (best value for guarded ratios, AND for boolean claims), so
    a claim that regressed in any split of the old row still fails."""
    merged = None
    for (n, ident), slot in current.items():
        if n != name:
            continue
        d = dict(ident)
        if any(d.get(k) != v for k, v in base_ident.items()):
            continue
        if merged is None:
            merged = dict(slot)
            continue
        for k, v in slot.items():
            if isinstance(v, bool):
                merged[k] = merged.get(k, True) and v
            else:
                merged[k] = max(merged.get(k, float("-inf")), v)
    return merged


def check_rows(baseline_rows: list[dict], rows: list[dict],
               factor: float = 0.5) -> list[str]:
    """Compare a run against a baseline; returns human-readable violations
    (empty = pass)."""
    violations = []
    current: dict[tuple, dict] = {}
    names_run = set()
    for r in rows:
        if r.get("derived", "").startswith("ERROR="):
            violations.append(f"{r['name']}: {r['derived']}")
            continue
        names_run.add(r["name"])
        key = _row_key(r)
        kv = parse_derived(r.get("derived", ""))
        slot = current.setdefault(key, {})
        for k, v in _guarded(kv).items():  # duplicates keep the best
            slot[k] = max(slot.get(k, float("-inf")), v)
        for k, v in kv.items():
            if v in BOOL_VALUES:
                # a single False among duplicates taints the claim
                slot[k] = slot.get(k, True) and v == "True"

    for b in baseline_rows:
        if b["name"] not in names_run:
            continue  # bench not selected this run (--only)
        key = _row_key(b)
        kv = parse_derived(b.get("derived", ""))
        cur = current.get(key)
        if cur is None:  # exact ident miss: try the additive-key fallback
            cur = _superset_match(current, b["name"], dict(key[1]))
        if cur is None:
            violations.append(f"{b['name']}{dict(key[1])}: row missing "
                              f"from current run")
            continue
        for k, base in _guarded(kv).items():
            got = cur.get(k)
            floor = max(base * factor, ABS_MIN.get((b["name"], k), 0.0))
            if got is None:
                violations.append(f"{b['name']}{dict(key[1])}: metric "
                                  f"{k} missing")
            elif got < floor:
                violations.append(
                    f"{b['name']}{dict(key[1])}: {k}={got:g} fell below "
                    f"its floor ({floor:g}; baseline {base:g}, "
                    f"factor {factor:g})")
        for k, v in kv.items():
            if v != "True" or k in HOST_SPEED_BOOL_KEYS:
                continue
            got = cur.get(k)
            if got is None:
                violations.append(
                    f"{b['name']}{dict(key[1])}: boolean claim {k} missing")
            elif got is False:
                violations.append(
                    f"{b['name']}{dict(key[1])}: {k} regressed to False")
    return violations
