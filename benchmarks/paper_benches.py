"""One benchmark per paper table/figure (see benchmarks.run for the CSV
contract).  Scale note: our ingest spec is a reduced pixel grid (DESIGN.md
§3), so absolute x-realtime numbers differ from the paper's Xeon/P6000
testbed; each bench reproduces the paper's *relative* claim."""

from __future__ import annotations

import time

import numpy as np

from repro.analytics.query import run_query
from repro.analytics.scene import generate_segment
from repro.codec import decode_segment, encode_segment
from repro.codec.transform import temporal_indices
from repro.core import coalesce
from repro.core.coalesce import _golden_node, _unique_nodes
from repro.core.knobs import (RESOLUTION_VALUES, SAMPLING_VALUES,
                              FidelityOption, IngestSpec, StorageFormat)
from repro.videostore import VideoStore

from .common import ACCURACIES, SPEC, config, profiler, row


def bench_fig3_coding():
    """Fig. 3: coding-knob impacts — (a) speed step trades encode time for
    size; (b) small keyframe intervals accelerate sparse-sampling decode."""
    frames, _ = generate_segment("tucson", 0, SPEC)
    from repro.core.knobs import SPEED_ZSTD_LEVEL
    for step, lvl in SPEED_ZSTD_LEVEL.items():
        t0 = time.perf_counter()
        blob = encode_segment(frames, quant_scale=2.0, keyframe_interval=50,
                              zstd_level=lvl)
        dt = time.perf_counter() - t0
        row("fig3a_speed_step", dt * 1e6,
            f"step={step};size_bytes={len(blob)}")
    f_sparse = FidelityOption(sampling=1 / 30)
    for kint in (5, 10, 50):
        blob = encode_segment(frames, quant_scale=2.0,
                              keyframe_interval=kint, zstd_level=3)
        want = temporal_indices(FidelityOption(), f_sparse, SPEC)
        t0 = time.perf_counter()
        for _ in range(3):
            decode_segment(blob, want)
        dt = (time.perf_counter() - t0) / 3
        row("fig3b_kframe_sparse_decode", dt * 1e6,
            f"kint={kint};speed_x={SPEC.segment_seconds / dt:.0f}")


def bench_fig4_knobs():
    """Fig. 4: fidelity knobs have high, complex impacts on accuracy and
    consumption cost (one knob varied, others fixed)."""
    prof = profiler()
    for op in ("license", "motion"):
        for res in (180, 400, 720):
            f = FidelityOption("best", 1.0, res, 1.0)
            t0 = time.perf_counter()
            acc, speed = prof.consumer_profile(op, f)
            dt = time.perf_counter() - t0
            row("fig4_resolution", dt * 1e6,
                f"op={op};res={res};acc={acc:.2f};speed_x={speed:.0f}")
        for q in ("best", "bad"):
            f = FidelityOption(q, 1.0, 720, 1.0)
            acc, speed = prof.consumer_profile(op, f)
            row("fig4_quality", 0.0,
                f"op={op};q={q};acc={acc:.2f};speed_x={speed:.0f}")


def bench_fig6_retrieval_bottleneck():
    """Fig. 6: decoding can be slower than consumption — the case for
    low-fidelity / RAW storage formats."""
    prof = profiler()
    for op, f in (("motion", FidelityOption("bad", 1.0, 180, 1 / 30)),
                  ("diff", FidelityOption("best", 1.0, 200, 2 / 3))):
        from repro.core.knobs import RAW, CodingOption
        _, consume = prof.consumer_profile(op, f)
        sf_coded = StorageFormat(f, CodingOption("fastest", 5))
        dec_coded = prof.retrieval_speed(sf_coded, f)
        dec_raw = prof.retrieval_speed(StorageFormat(f, RAW), f)
        row("fig6_retrieval_vs_consumption", 0.0,
            f"op={op};consume_x={consume:.0f};decode_coded_x={dec_coded:.0f}"
            f";decode_raw_x={dec_raw:.0f}"
            f";bottleneck={'decode' if dec_coded < consume else 'consume'}")


def bench_table2_configuration():
    """Table 2: the automatically derived CF/SF configuration."""
    cfg = config()
    row("table2_derive", cfg.derive_seconds * 1e6,
        f"consumers={len(cfg.plans)};unique_cfs="
        f"{len({p.cf for p in cfg.plans})};sfs={len(cfg.nodes)}")
    for p in sorted(cfg.plans, key=lambda p: (p.consumer.op,
                                              -p.consumer.target)):
        row("table2_cf", 0.0,
            f"{p.consumer.name()};cf={p.cf.name()};acc={p.accuracy:.2f};"
            f"speed_x={p.speed:.0f};sf={cfg.subscription(p.cf)}")
    for i, n in enumerate(cfg.nodes):
        row("table2_sf", 0.0,
            f"{cfg.node_id(i)};{n.sf.name()};golden={n.golden}")


def _alt_configs():
    """VStore vs the paper's alternatives: 1->1, 1->N, N->N."""
    cfg = config()
    prof = profiler()
    golden = next(n for n in cfg.nodes if n.golden)
    golden_f = golden.fidelity
    # The 1->1 / 1->N baselines model a classic video database: it stores
    # the golden version ENCODED (paper: ingest transcodes to the richest
    # fidelity) — storing raw 24/7 footage is not a real alternative.
    from repro.core.knobs import GOLDEN_CODING
    alts = {}
    # 1->1: golden only; consumers consume golden fidelity
    alts["1to1"] = {"formats": {"sf_g": StorageFormat(golden_f,
                                                      GOLDEN_CODING)},
                    "cf_map": lambda p: golden_f,
                    "sub": lambda p: "sf_g"}
    # 1->N: golden only; consumers keep their derived CFs
    alts["1toN"] = {"formats": {"sf_g": StorageFormat(golden_f,
                                                      GOLDEN_CODING)},
                    "cf_map": lambda p: p.cf,
                    "sub": lambda p: "sf_g"}
    # N->N: one SF per unique CF (no coalescing) + golden
    n2n_nodes = _unique_nodes(cfg.plans, prof) + [_golden_node(cfg.plans)]
    fmts = {f"sf{i}": n.sf for i, n in enumerate(n2n_nodes)}
    cf_to_id = {}
    for i, n in enumerate(n2n_nodes):
        for p in n.plans:
            cf_to_id[p.cf] = f"sf{i}"
    alts["NtoN"] = {"formats": fmts,
                    "cf_map": lambda p: p.cf,
                    "sub": lambda p, m=cf_to_id: m[p.cf]}
    return alts


class _AltConfig:
    def __init__(self, base, cf_map, sub):
        self._base, self._cf_map, self._sub = base, cf_map, sub
        self._by_key = {(p.consumer.op, round(p.consumer.target, 4)): p
                        for p in base.plans}

    def consumption_format(self, op, acc):
        return self._cf_map(self._by_key[(op, round(acc, 4))])

    def subscription(self, cf):
        for key, p in self._by_key.items():
            if self._cf_map(p) == cf:
                return self._sub(p)
        raise KeyError(cf)


def bench_fig11_end_to_end(tmp_root="/tmp/repro_bench_store"):
    """Fig. 11: query speed / storage / ingestion cost — VStore vs
    1->1, 1->N, N->N."""
    import shutil
    cfg = config()
    n_segs = 3
    setups = {"vstore": {"formats": cfg.storage_formats(),
                         "cfg": cfg}}
    for name, alt in _alt_configs().items():
        setups[name] = {"formats": alt["formats"],
                        "cfg": _AltConfig(cfg, alt["cf_map"], alt["sub"])}

    for name, setup in setups.items():
        root = f"{tmp_root}/{name}"
        shutil.rmtree(root, ignore_errors=True)
        vs = VideoStore(root, SPEC)
        vs.set_formats(setup["formats"])
        t0 = time.perf_counter()
        for seg in range(n_segs):
            frames, _ = generate_segment("jackson", seg, SPEC)
            vs.ingest_segment("jackson", seg, frames)
        st = vs.ingest_stats["jackson"]
        row("fig11b_storage", 0.0,
            f"config={name};bytes_per_videosec="
            f"{st.bytes_per_video_second(SPEC):.0f}")
        row("fig11c_ingest", st.encode_seconds * 1e6,
            f"config={name};ingest_x={st.cost_xrealtime(SPEC):.3f}")
        for acc in ACCURACIES:
            run_query(vs, setup["cfg"], "A", "jackson",
                      list(range(n_segs)), acc)   # warm up jit caches
            t0 = time.perf_counter()
            res = run_query(vs, setup["cfg"], "A", "jackson",
                            list(range(n_segs)), acc)
            dt = time.perf_counter() - t0
            row("fig11a_query_speed", dt * 1e6,
                f"config={name};acc={acc};speed_x={res.pipelined_speed:.0f}")


def bench_fig12_erosion():
    """Fig. 12: age-based decay — gentler for bigger budgets; golden
    intact."""
    from repro.core.erosion import plan_erosion
    cfg = config()
    prof = profiler()
    subs = {}
    for i, node in enumerate(cfg.nodes):
        for p in node.plans:
            subs[p] = i
    daily = [prof.storage_profile(n.sf)[1] * 86400 for n in cfg.nodes]
    full = sum(daily) * 10
    for frac in (0.8, 0.5, 0.3):
        t0 = time.perf_counter()
        plan = plan_erosion(prof, cfg.nodes, subs, daily, 10, frac * full)
        dt = time.perf_counter() - t0
        golden_idx = next(i for i, n in enumerate(cfg.nodes) if n.golden)
        golden_intact = all(f.get(golden_idx, 0) == 0
                            for f in plan.fractions)
        row("fig12_erosion", dt * 1e6,
            f"budget_frac={frac};k={plan.k:.2f};feasible={plan.feasible};"
            f"day1_speed={plan.overall_speed[0]:.2f};"
            f"day10_speed={plan.overall_speed[-1]:.2f};"
            f"golden_intact={golden_intact}")


def bench_table3_ingest_budget():
    """Table 3: decreasing ingestion budget -> cheaper coding, then forced
    coalescing, with a small storage increase.  Restricted to the slow
    consumers (nn/ocr/license) whose storage formats are coded — RAW
    formats have no transcode cost to trade (DESIGN.md §3: the CPU decode/
    consume balance shifts more consumers onto RAW than the paper's
    NVDEC testbed)."""
    cfg = config()
    prof = profiler()
    slow_plans = [p for p in cfg.plans
                  if p.consumer.op in ("nn", "ocr", "license")]
    free = coalesce(prof, slow_plans)
    for frac in (1.0, 0.7, 0.4):
        t0 = time.perf_counter()
        res = coalesce(prof, slow_plans,
                       ingest_budget=free.ingest_cost * frac)
        dt = time.perf_counter() - t0
        codings = "|".join(sorted(n.coding.name() for n in res.nodes))
        row("table3_budget", dt * 1e6,
            f"budget_frac={frac};ingest={res.ingest_cost:.3f};"
            f"storage={res.storage_cost:.0f};n_sfs={len(res.nodes)};"
            f"met={res.budget_met};codings={codings}")


def bench_serve_concurrency(tmp_root="/tmp/repro_bench_serve"):
    """Beyond-paper: concurrent query serving (repro.serving).  Aggregate
    x-realtime and decoded-segment cache hit rate at 1/4/16 concurrent
    queries over shared segments, vs the same workload as sequential
    ``run_query`` calls — the cache + shared-retrieval planner + request
    collapsing should multiply aggregate throughput, with results
    bit-identical to the sequential baseline."""
    import shutil

    from repro.serving import VStoreServer

    cfg = config()
    n_segs = 3
    shutil.rmtree(tmp_root, ignore_errors=True)
    vs = VideoStore(f"{tmp_root}/store", SPEC)
    vs.set_formats(cfg.storage_formats())
    for seg in range(n_segs):
        frames, _ = generate_segment("jackson", seg, SPEC)
        vs.ingest_segment("jackson", seg, frames)
    segs = list(range(n_segs))

    def workload(n):
        mix = [(q, a) for q in ("A", "B") for a in ACCURACIES]
        return [(mix[i % len(mix)][0], "jackson", segs, mix[i % len(mix)][1])
                for i in range(n)]

    baseline = {}  # warm jit caches + golden item sets
    for q, stream, sg, acc in workload(16):
        if (q, acc) not in baseline:
            baseline[(q, acc)] = run_query(vs, cfg, q, stream, sg, acc)
            # also warm the static batch shapes the server's batched
            # consumption path uses (VStoreServer default batch_segments=4)
            run_query(vs, cfg, q, stream, sg, acc, batch_segments=4)

    for n in (1, 4, 16):
        subs = workload(n)
        t0 = time.perf_counter()
        for q, stream, sg, acc in subs:
            run_query(vs, cfg, q, stream, sg, acc)
        seq_wall = time.perf_counter() - t0

        with VStoreServer(vs, cfg, workers=4, max_inflight=n) as srv:
            t0 = time.perf_counter()
            results = srv.run_batch(subs)
            wall = time.perf_counter() - t0
            st = srv.stats()
        identical = all(r.items == baseline[(q, acc)].items
                        for r, (q, _s, _sg, acc) in zip(results, subs))
        vsec = n * n_segs * SPEC.segment_seconds
        row("serve_concurrency", wall * 1e6,
            f"n={n};agg_x={vsec / wall:.0f};seq_x={vsec / seq_wall:.0f};"
            f"speedup={seq_wall / wall:.2f};"
            f"hit_rate={st['cache']['hit_rate']:.2f};"
            f"collapsed={st['collapsed']};decodes={st['decodes']};"
            f"coalesced_cfs={st['coalesced_cfs']};identical={identical}")


def bench_batched_consumption(tmp_root="/tmp/repro_bench_batched"):
    """Beyond-paper: cross-segment batched consumption (repro.analytics.batch).

    A multi-stage cascade with sparse late-stage activation pays a jit
    dispatch per segment per stage on the per-segment path; fusing many
    segments' activated frames into one detect per static shape bucket
    keeps the operator — not dispatch — the bottleneck.  Reports per-stage
    detect-call counts and measured x-realtime for the per-segment
    baseline, batched run_query, and the batched pipelined executor; items
    must be identical throughout.  Uses a hand-built two-SF configuration
    (no profiling) so the bench runs in seconds on CI."""
    import shutil

    from repro.launch.vserve import demo_config
    from repro.serving.executor import run_pipelined

    cfg = demo_config()
    n_segs = 12
    shutil.rmtree(tmp_root, ignore_errors=True)
    vs = VideoStore(f"{tmp_root}/store", SPEC)
    vs.set_formats(cfg.storage_formats())
    for seg in range(n_segs):
        frames, _ = generate_segment("jackson", seg, SPEC)
        vs.ingest_segment("jackson", seg, frames)
    segs = list(range(n_segs))

    def timed(fn, repeats=3):
        fn()  # warm jit caches
        t0 = time.perf_counter()
        outs = [fn() for _ in range(repeats)]
        return (time.perf_counter() - t0) / repeats, outs[-1]

    for q, acc in (("A", 0.8), ("B", 0.8)):
        base_t, base = timed(
            lambda: run_query(vs, cfg, q, "jackson", segs, acc))
        bat_t, bat = timed(
            lambda: run_query(vs, cfg, q, "jackson", segs, acc,
                              batch_segments=n_segs))
        pip_t, pip = timed(
            lambda: run_pipelined(vs, cfg, q, "jackson", segs, acc,
                                  prefetch_depth=2, batch_segments=6))
        vsec = n_segs * SPEC.segment_seconds
        identical = bat.items == base.items and pip.items == base.items
        fewer = all(b.detect_calls <= s.detect_calls
                    for s, b in zip(base.stages, bat.stages))
        for s, b in zip(base.stages, bat.stages):
            row("batched_consumption_stage", 0.0,
                f"query={q};op={s.op};seq_calls={s.detect_calls};"
                f"batched_calls={b.detect_calls};frames={b.frames};"
                f"batched_frames={b.batched_frames}")
        row("batched_consumption", bat_t * 1e6,
            f"query={q};acc={acc};segments={n_segs};"
            f"seq_x={vsec / base_t:.0f};batched_x={vsec / bat_t:.0f};"
            f"pipelined_x={vsec / pip_t:.0f};"
            f"speedup={base_t / bat_t:.2f};"
            f"seq_calls={sum(s.detect_calls for s in base.stages)};"
            f"batched_calls={sum(s.detect_calls for s in bat.stages)};"
            f"identical={identical};fewer_calls={fewer}")


def bench_cross_query_batching(tmp_root="/tmp/repro_bench_xquery"):
    """Beyond-paper: continuous cross-query batching (repro.serving.sched).

    16 concurrent queries at 4x duplication — the demo configuration maps
    accuracies 0.8 and 0.9 to the *same* CFs per op, so the four live keys
    (A/B x two accuracies) are distinct (whole-query collapsing can't fuse
    them, and it is disabled in both arms) while their per-frame work is
    pairwise identical.  The shared consumption scheduler must (a) cut
    fused detect calls to <= 0.5x the per-query-batching count
    (``call_reduction`` >= 2, factor- and floor-gated), (b) hold aggregate
    serving speed at >= 1.5x realtime (host-speed claim, reported), and
    (c) return every query's items bit-identical to sequential
    ``run_query`` (exact-gated)."""
    import shutil

    from repro.launch.vserve import demo_config
    from repro.serving import VStoreServer

    cfg = demo_config()
    n, dup, n_segs = 16, 4, 4
    shutil.rmtree(tmp_root, ignore_errors=True)
    vs = VideoStore(f"{tmp_root}/store", SPEC)
    vs.set_formats(cfg.storage_formats())
    for seg in range(n_segs):
        frames, _ = generate_segment("jackson", seg, SPEC)
        vs.ingest_segment("jackson", seg, frames)
    segs = list(range(n_segs))

    mix = [("A", 0.8), ("A", 0.9), ("B", 0.8), ("B", 0.9)]
    subs = [(mix[i % dup][0], "jackson", segs, mix[i % dup][1])
            for i in range(n)]
    golden = {}  # warm jit caches (per-segment + static batch shapes)
    for q, _s, sg, acc in subs:
        if (q, acc) not in golden:
            golden[(q, acc)] = run_query(vs, cfg, q, "jackson", sg, acc)
            run_query(vs, cfg, q, "jackson", sg, acc, batch_segments=4)

    def arm(cross):
        # workers == n so every query is in flight at once: co-batching
        # partners must actually overlap for the scheduler to fuse them
        with VStoreServer(vs, cfg, workers=n, max_inflight=n,
                          collapse=False, cross_query_batching=cross,
                          batch_max_wait_ms=20.0) as srv:
            srv.run_batch(subs)  # warm the server path itself
            t0 = time.perf_counter()
            results = srv.run_batch(subs)
            wall = time.perf_counter() - t0
            return wall, results, srv.stats()

    base_wall, base_res, _ = arm(cross=False)
    sched_wall, sched_res, st = arm(cross=True)

    base_calls = sum(s.detect_calls for r in base_res for s in r.stages)
    sched_calls = sum(s.detect_calls for r in sched_res for s in r.stages)
    identical = all(
        r.items == golden[(q, acc)].items
        for res in (base_res, sched_res)
        for r, (q, _s, _sg, acc) in zip(res, subs))
    vsec = n * n_segs * SPEC.segment_seconds
    agg_x = vsec / sched_wall
    row("cross_query_batching", sched_wall * 1e6,
        f"n={n};dup={dup};segments={n_segs};"
        f"base_x={vsec / base_wall:.0f};agg_x={agg_x:.0f};"
        f"speedup={base_wall / sched_wall:.2f};"
        f"base_calls={base_calls};sched_calls={sched_calls};"
        f"call_reduction={base_calls / max(1, sched_calls):.2f};"
        f"deduped={st['sched_deduped']};"
        f"fusion_ratio={st['sched_fusion_ratio']:.2f};"
        f"occupancy={st['sched_batch_occupancy']:.2f};"
        f"identical={identical};realtime_1_5x={agg_x >= 1.5}")


def bench_ingest_live(tmp_root="/tmp/repro_bench_ingest"):
    """Beyond-paper: the live ingestion subsystem (repro.ingest).

    4 simulated camera streams feed the budgeted scheduler with a transcode
    budget *below* the full materialization cost: golden ingest must hold
    >= 1x realtime (durability never lags the cameras), queries issued
    mid-ingest — storage formats still queued — must return items identical
    to the fully materialized store (fallback-chain retrieval is bit-exact
    by construction), and the accumulated transcode debt must drain to zero
    once the budget is raised.  A final erosion sweep ages the footage and
    reports bytes actually reclaimed (chunk-span accounting from blob v2).
    Uses the hand-built demo configuration so the bench runs in seconds."""
    import shutil

    from repro.core.erosion import ErosionPlan
    from repro.ingest import ErosionExecutor, IngestScheduler
    from repro.launch.vserve import demo_config

    cfg = demo_config()
    streams = ("jackson", "miami", "tucson", "dashcam")
    n_segs = 2
    shutil.rmtree(tmp_root, ignore_errors=True)
    vs = VideoStore(f"{tmp_root}/store", SPEC)
    vs.set_formats(cfg.storage_formats())

    # calibrate: one blocking full-materialization ingest on this machine,
    # plus the golden share of it — the budget sits above golden (ingest
    # durability must never starve) but covers only a quarter of the
    # remaining background transcode cost, so debt accumulates
    probe, _ = generate_segment(streams[0], 0, SPEC)
    vs.ingest_segment("_probe", 0, probe)  # warm the jit caches first
    t0 = time.perf_counter()
    vs.ingest_segment("_probe", 1, probe)
    full_x = (time.perf_counter() - t0) / SPEC.segment_seconds
    for sid in vs.formats:
        vs.erode("_probe", sid, 1.0)
    t0 = time.perf_counter()
    golden_sf = next(sid for sid in cfg.storage_formats() if sid == "sf_g")
    vs.encode_format(probe, FidelityOption(), vs.formats[golden_sf])
    golden_cost_x = (time.perf_counter() - t0) / SPEC.segment_seconds

    # just enough budget for golden plus a 5% margin — the background
    # queue is nearly starved so debt accumulates regardless of probe
    # noise; capped below the full cost so the premise (budget < full
    # materialization) holds on any host
    budget_x = min(1.05 * golden_cost_x, 0.9 * full_x)
    sched = IngestScheduler(vs, cfg, budget_x=budget_x)
    t0 = time.perf_counter()
    for seg in range(n_segs):
        for stream in streams:
            frames, _ = generate_segment(stream, seg, SPEC)
            sched.ingest(stream, seg, frames)
            sched.pump()  # budget-gated background transcode cycles
    ingest_wall = time.perf_counter() - t0
    st = sched.stats()
    vsec = st["video_seconds"]
    golden_x = min(s["golden_x"] for s in st["streams"].values())
    debt_before = st["debt_s"]
    pending_before = st["pending"]

    # mid-ingest queries: unmaterialized formats served over the fallback
    # chain (warm once per query for jit, then take the answer)
    segs = list(range(n_segs))
    mid = {}
    t_mid = {}
    for q, stream in (("A", streams[0]), ("B", streams[1])):
        run_query(vs, cfg, q, stream, segs, 0.8)
        t0 = time.perf_counter()
        mid[q] = run_query(vs, cfg, q, stream, segs, 0.8)
        t_mid[q] = time.perf_counter() - t0
    fb_reads = sched.fallback.stats()["fallback_reads"]

    # raise the budget: the debt must drain to zero
    t0 = time.perf_counter()
    sched.set_budget_x(None)
    drained_tasks = sched.drain()
    drain_wall = time.perf_counter() - t0
    debt_after = sched.debt_seconds()

    identical = True
    t_full = {}
    for q, stream in (("A", streams[0]), ("B", streams[1])):
        t0 = time.perf_counter()
        full = run_query(vs, cfg, q, stream, segs, 0.8)
        t_full[q] = time.perf_counter() - t0
        identical &= full.items == mid[q].items

    row("ingest_live", ingest_wall * 1e6,
        f"streams={len(streams)};segments={n_segs};"
        f"budget_x={budget_x:.2f};full_x={full_x:.2f};"
        f"sustain_x={vsec / ingest_wall:.1f};golden_x={golden_x:.0f};"
        f"golden_realtime={golden_x >= 1.0};"
        f"debt_before_s={debt_before:.2f};pending_before={pending_before};"
        f"fallback_reads={fb_reads};identical={identical}")
    row("ingest_live_drain", drain_wall * 1e6,
        f"streams={len(streams)};drained_tasks={drained_tasks};"
        f"debt_after_s={debt_after:.2f};drained={debt_after == 0};"
        f"q_mid_ms={sum(t_mid.values()) * 1e3:.0f};"
        f"q_full_ms={sum(t_full.values()) * 1e3:.0f}")

    # erosion executor: age the (now fully materialized) footage and
    # reclaim bytes; queries keep answering over the fallback chain
    plan = ErosionPlan(k=1.0, ages=[1], fractions=[{0: 0.5}],
                       overall_speed=[0.9], daily_bytes=[0.0],
                       total_bytes=0.0, feasible=True)
    node_ids = [cfg.node_id(i) for i in range(len(cfg.nodes))]
    executor = ErosionExecutor(vs, plan, node_ids)
    executor.register_existing(list(streams))
    b0 = vs.storage_bytes()
    rep = executor.advance()
    reclaimed = b0 - vs.storage_bytes()
    res = run_query(vs, cfg, "A", streams[0], segs, 0.8)
    row("ingest_live_erosion", 0.0,
        f"streams={len(streams)};eroded_segments={rep.segments};"
        f"eroded_bytes={rep.bytes};chunks={rep.chunks};"
        f"chunk_bytes={rep.chunk_bytes};reclaimed={reclaimed};"
        f"bytes_reclaimed={reclaimed > 0};"
        f"post_erosion_identical={res.items == mid['A'].items}")


def bench_predicate_pushdown(tmp_root="/tmp/repro_bench_pushdown"):
    """Beyond-paper: ingest-time semantic indexing (repro.index).

    12 segments, 2 with street activity and 10 static: cascade-head
    sketches let exact predicate pushdown skip the inactive segments
    before the store read and decoder.  The gate is the acceptance claim:
    >= 5x fewer stage-0 decoded segments with items bit-identical (exact
    mode must never change an answer)."""
    import shutil

    from repro.index import SemanticIndex
    from repro.launch.vserve import demo_config

    cfg = demo_config(index_ops=("diff", "motion"))
    n_segs, active = 12, 2
    shutil.rmtree(tmp_root, ignore_errors=True)
    vs = VideoStore(f"{tmp_root}/store", SPEC)
    vs.set_formats(cfg.storage_formats())
    # scene segments 1 and 6 activate BOTH head ops at their sketch knobs
    # (and survive the full cascade: the identity is over non-empty items)
    for pos, scene in enumerate((1, 6)):
        frames, _ = generate_segment("jackson", scene, SPEC)
        vs.ingest_segment("jackson", pos, frames)
    static = np.full((SPEC.frames_per_segment, SPEC.height, SPEC.width),
                     127, np.uint8)
    for pos in range(active, n_segs):
        vs.ingest_segment("jackson", pos, static)

    idx = SemanticIndex(f"{tmp_root}/index", SPEC, cfg)
    t0 = time.perf_counter()
    for pos in range(n_segs):
        for op in cfg.index_ops:
            idx.build(vs, "jackson", pos, op)
    build_wall = time.perf_counter() - t0
    idx.flush()

    segs = list(range(n_segs))
    for q in ("A", "B"):
        run_query(vs, cfg, q, "jackson", segs, 0.8)  # warm jit caches
        t0 = time.perf_counter()
        plain = run_query(vs, cfg, q, "jackson", segs, 0.8)
        t_plain = time.perf_counter() - t0
        t0 = time.perf_counter()
        pushed = run_query(vs, cfg, q, "jackson", segs, 0.8, index=idx)
        t_push = time.perf_counter() - t0
        d_plain = plain.stages[0].segments_scanned
        d_push = pushed.stages[0].segments_scanned
        row("predicate_pushdown", t_push * 1e6,
            f"query={q};segments={n_segs};active={active};mode=exact;"
            f"decoded_plain={d_plain};decoded_pushed={d_push};"
            f"decode_reduction={d_plain / max(1, d_push):.1f};"
            f"identical={pushed.items == plain.items};"
            f"nonempty={bool(plain.items)};"
            f"pruned={pushed.pruned_segments};"
            f"pruned_bytes={pushed.pruned_bytes};"
            f"speedup={t_plain / t_push:.2f}")
    row("predicate_pushdown_build", build_wall * 1e6,
        f"segments={n_segs};index_bytes={idx.store.total_bytes()};"
        f"builds={idx.stats()['index_builds']};"
        f"build_ms_per_seg={build_wall * 1e3 / n_segs:.1f}")


def bench_ingest_soak(tmp_root="/tmp/repro_bench_soak"):
    """Beyond-paper: arrival-paced soak of the live ingest path with
    sketching in the mix.  Two cameras at pace_x=1.0 (1-second segments)
    feed the budgeted scheduler plus the semantic-index sketcher; the
    claim is stationarity — transcode debt does not trend upward across
    the run, because the budget (calibrated with headroom over the
    measured full-materialization cost) keeps up with realtime arrivals
    even while also paying for sketch builds."""
    import shutil

    from repro.index import SemanticIndex
    from repro.ingest import IngestScheduler, StreamSource, interleave
    from repro.launch.vserve import demo_config

    spec = IngestSpec(segment_seconds=1)
    cfg = demo_config(index_ops=("diff", "motion"))
    shutil.rmtree(tmp_root, ignore_errors=True)
    vs = VideoStore(f"{tmp_root}/store", spec)
    vs.set_formats(cfg.storage_formats())

    # calibrate: one blocking full-materialization ingest (after a warm-up
    # pass so jit compile time doesn't inflate the estimate)
    probe, _ = generate_segment("jackson", 0, spec)
    vs.ingest_segment("_probe", 0, probe)
    t0 = time.perf_counter()
    vs.ingest_segment("_probe", 1, probe)
    full_x = (time.perf_counter() - t0) / spec.segment_seconds
    for sid in vs.formats:
        vs.erode("_probe", sid, 1.0)

    budget_x = 2.0 * full_x  # headroom: transcodes + sketches fit
    sched = IngestScheduler(vs, cfg, budget_x=budget_x)
    index = SemanticIndex(f"{tmp_root}/index", spec, cfg)
    sched.attach_sketcher(index)

    n_segs = 8
    sources = [StreamSource(s, spec, n_segs)
               for s in ("jackson", "tucson")]
    debts = []
    t0 = time.perf_counter()
    for arr in interleave(sources, pace_x=1.0):
        sched.ingest(arr.stream, arr.seg, arr.frames)
        sched.pump()  # budget-gated background cycles between arrivals
        debts.append(sched.debt_seconds())
    wall = time.perf_counter() - t0
    st = sched.stats()
    half = len(debts) // 2
    drift = (sum(debts[half:]) / len(debts[half:])
             - sum(debts[:half]) / len(debts[:half]))
    stationary = drift <= 0.25 * spec.segment_seconds
    max_lag = max(s["max_golden_lag_s"] for s in st["streams"].values())
    vsec = st["video_seconds"]
    row("ingest_soak", wall * 1e6,
        f"streams=2;segments={n_segs};pace=1.0;budget_x={budget_x:.2f};"
        f"full_x={full_x:.2f};sustain_x={vsec / wall:.2f};"
        f"debt_drift_s={drift:.3f};debt_end_s={debts[-1]:.3f};"
        f"stationary={stationary};max_golden_lag_ms={max_lag * 1e3:.0f};"
        f"sketches={st['sketches']};sketched={st['sketches'] > 0};"
        f"sketch_pending={st['sketch_pending']};pending={st['pending']}")


_BURN_SRC = ("import time\n"
             "t0 = time.perf_counter(); n = 0\n"
             "while time.perf_counter() - t0 < 0.5: n += 1\n"
             "print(n)\n")


def _host_parallel_x() -> float:
    """How much *parallel* CPU this host actually grants two busy
    processes, as a multiple of one process's throughput (~2.0 on a real
    2-core box; overcommitted CI sandboxes measurably sit near 1.2-1.5).
    The cluster_scaling speedup is bounded above by this number, so the
    bench reports it alongside.  Bare subprocess busy loops — no jax, no
    fork of this (multithreaded) process."""
    import subprocess
    import sys

    def burn(k: int) -> list[int]:
        procs = [subprocess.Popen([sys.executable, "-c", _BURN_SRC],
                                  stdout=subprocess.PIPE)
                 for _ in range(k)]
        return [int(p.communicate()[0]) for p in procs]

    try:
        serial = burn(1)[0]
        return sum(burn(2)) / max(serial, 1)
    except (OSError, ValueError):
        return float("nan")


def bench_cluster_scaling(tmp_root="/tmp/repro_bench_cluster"):
    """Beyond-paper: stream-sharded multi-process serving (repro.cluster).

    The thread-based server is GIL-capped (~1.7x aggregate on a 2-core
    host); sharding streams across worker *processes* is the scale-out
    path.  Builds the same 4-stream store as a 1-shard and a 2-shard
    cluster (each worker a full per-shard stack with the process-per-core
    isolated runtime), scatters an identical 16-query mix through the
    router, and compares aggregate x-realtime.  Timed windows are
    interleaved 1-shard/2-shard so host-capacity noise (shared CI boxes)
    hits both configurations alike, and the best window per configuration
    is reported (the repo's min-of-repeats idiom).  Items must be
    bit-identical to the single-process ``run_query`` reference, and the
    cluster's rolled-up stats must account every submission.

    ``speedup`` is a same-host ratio of two simultaneous configurations —
    but unlike single-process ratios it also depends on how much *parallel*
    CPU the host actually grants (overcommitted CI sandboxes measurably cap
    two busy processes below 1.5x of one), so the ``scales`` >= 1.5x claim
    is exempted from the exact gate via ``HOST_SPEED_BOOL_KEYS``."""
    import itertools
    import shutil

    from repro.cluster import ShardRouter
    from repro.launch.vserve import demo_config

    cfg = demo_config()
    streams = ["jackson", "miami", "tucson", "dashcam"]  # 2/2 shard split
    n_segs = 3
    segs = list(range(n_segs))
    subs = [(q, s, segs, a) for s, (q, a) in itertools.product(
        streams, [("A", 0.8), ("B", 0.8), ("A", 0.9), ("B", 0.9)])]
    vsec = len(subs) * n_segs * SPEC.segment_seconds

    shutil.rmtree(tmp_root, ignore_errors=True)
    ref = VideoStore(f"{tmp_root}/ref", SPEC)
    cfg_formats = cfg.storage_formats()
    ref.set_formats(cfg_formats)
    frames_by_key = {}
    for s in streams:
        for g in segs:
            frames_by_key[(s, g)] = generate_segment(s, g, SPEC)[0]
            ref.ingest_segment(s, g, frames_by_key[(s, g)])
    base = {(q, s, acc): run_query(ref, cfg, q, s, segs, acc)
            for q, s, _sg, acc in subs}

    routers, walls, results = {}, {1: [], 2: []}, {}
    try:
        for n in (1, 2):
            # registered before start(): a setup failure must still shut
            # the spawned workers down in the finally below
            routers[n] = r = ShardRouter(f"{tmp_root}/c{n}", cfg, n,
                                         spec=SPEC, opts={"workers": 1})
            r.start()
            for (s, g), frames in frames_by_key.items():
                r.ingest(s, g, frames)
            r.query_many(subs)  # warm per-worker jit + decoded caches
        for _ in range(4):  # interleaved timing windows
            for n, r in routers.items():
                t0 = time.perf_counter()
                results[n] = r.query_many(subs)
                walls[n].append(time.perf_counter() - t0)
        stats = {n: r.stats() for n, r in routers.items()}
    finally:
        for r in routers.values():
            r.close()

    agg = {n: vsec / min(w) for n, w in walls.items()}
    speedup = agg[2] / agg[1]
    host_x = _host_parallel_x()
    # the machine-aware claim: the cluster realizes at least 75% of the
    # parallel CPU this host actually grants two processes (>= 1.5x on a
    # genuine 2-core box, where host_x ~= 2.0).  Informative alongside
    # `scales`, not exactly gated — the spin-loop calibration has no
    # memory/IPC contention and samples a different moment than the timed
    # windows (both are in HOST_SPEED_BOOL_KEYS; the factor-gated
    # `speedup` ratio is the enforceable regression guard).  Vacuously
    # true when the calibration couldn't run (NaN).
    scales_to_host = (host_x != host_x
                      or speedup >= 0.75 * min(host_x, 2.0))
    for n in (1, 2):
        identical = all(res.items == base[(q, s, acc)].items
                        for res, (q, s, _sg, acc) in zip(results[n], subs))
        st = stats[n]
        accounted = (st["completed"] >= 5 * len(subs)  # warm + 4 windows
                     and st["failed"] == 0 and st["restarts"] == 0)
        extra = "" if n == 1 else (
            f"speedup={speedup:.2f};host_parallel_x={host_x:.2f};"
            f"scales={speedup >= 1.5};scales_to_host={scales_to_host};")
        row("cluster_scaling", min(walls[n]) * 1e6,
            f"shards={n};n={len(subs)};segments={n_segs};"
            f"agg_x={agg[n]:.0f};{extra}"
            f"identical={identical};accounted={accounted}")


def bench_decode_path(n_segs=8, kint=10):
    """Beyond-paper: the fused batched decode path (blob format v2 +
    one-dispatch residual IDCT) vs the seed decoder.

    The seed decoder (``decode_segment_scan``) entropy-decodes the whole
    v1 payload and runs one jit dispatch per chunk with the IDCT inside
    the DPCM scan; the fused path (``decode_many`` on v2 blobs) touches
    only the wanted chunks' payload spans and reconstructs every wanted
    chunk of the whole segment group in one batched residual-IDCT
    dispatch.  Reports x-realtime and touched bytes at dense and
    1/30-sparse sampling; outputs must be bit-identical."""
    from repro.codec.segment import decode_many, decode_segment_scan

    frames = [generate_segment("tucson", i, SPEC)[0] for i in range(n_segs)]
    enc = lambda f, v: encode_segment(  # noqa: E731
        f, quant_scale=2.0, keyframe_interval=kint, zstd_level=3, version=v)
    blobs_v1 = [enc(f, 1) for f in frames]
    blobs_v2 = [enc(f, 2) for f in frames]

    def timed(fn, repeats=5):
        fn(), fn()  # warm jit caches
        t0 = time.perf_counter()
        outs = [fn() for _ in range(repeats)]
        return (time.perf_counter() - t0) / repeats, outs[-1]

    vsec = n_segs * SPEC.segment_seconds
    for name, sampling in (("dense", 1.0), ("sparse", 1 / 30)):
        want = temporal_indices(FidelityOption(),
                                FidelityOption(sampling=sampling), SPEC)
        t_seed, seed_out = timed(
            lambda: [decode_segment_scan(b, want) for b in blobs_v1])
        t_fused, fused = timed(lambda: decode_many(blobs_v2, want))
        fused_out, cost = fused
        identical = all(np.array_equal(a, b)
                        for a, b in zip(seed_out, fused_out))
        row("decode_path", t_fused * 1e6,
            f"mode={name};segments={n_segs};kint={kint};"
            f"seed_x={vsec / t_seed:.0f};fused_x={vsec / t_fused:.0f};"
            f"speedup={t_seed / t_fused:.2f};"
            f"bytes_total={sum(len(b) for b in blobs_v2)};"
            f"bytes_touched={cost['bytes']};dispatches={cost['dispatches']};"
            f"identical={identical}")


def bench_fig13_overhead():
    """Fig. 13 / §6.4: boundary-search + memoization profiling overhead vs
    exhaustive profiling of the full fidelity space."""
    prof = profiler()
    stats = prof.stats
    n_fidelities = 4 * 3 * len(RESOLUTION_VALUES) * len(SAMPLING_VALUES)
    ops = 6
    exhaustive_runs = ops * n_fidelities
    mean_run_s = stats.wall_seconds / max(stats.consumption_runs +
                                          stats.storage_runs, 1)
    row("fig13_overhead", stats.wall_seconds * 1e6,
        f"profiling_runs={stats.consumption_runs + stats.storage_runs};"
        f"memo_hits={stats.memo_hits};"
        f"exhaustive_runs={exhaustive_runs};"
        f"run_reduction_x={exhaustive_runs / max(stats.consumption_runs, 1):.1f};"
        f"est_exhaustive_s={exhaustive_runs * mean_run_s:.0f}")


def bench_obs_overhead(tmp_root="/tmp/repro_bench_obs"):
    """Beyond-paper: tracing instrumentation cost (repro.obs).

    The disabled ``span()`` fast path is one attribute read plus a shared
    no-op context manager; this bench measures that cost directly (ns per
    call), then bounds the whole-query impact as spans-per-query (counted
    from one traced run) x the disabled call cost over the untraced query
    wall time — gated ``low_overhead`` below 3%.  A traced run must also
    produce a loadable Chrome trace whose parent links all resolve
    (``trace_valid``) with items bit-identical to the untraced run
    (``identical``): tracing observes the data path, never perturbs it."""
    import json
    import os
    import shutil

    from repro import obs
    from repro.launch.vserve import demo_config

    # -- micro: cost of one instrumented call site while tracing is off
    obs.enable(False)
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("bench.noop", k=1):
            pass
    ns_disabled = (time.perf_counter() - t0) / n * 1e9

    # -- macro: the full cascade data path, untraced vs traced windows
    cfg = demo_config()
    n_segs = 2
    shutil.rmtree(tmp_root, ignore_errors=True)
    vs = VideoStore(f"{tmp_root}/store", SPEC)
    vs.set_formats(cfg.storage_formats())
    for seg in range(n_segs):
        frames, _ = generate_segment("jackson", seg, SPEC)
        vs.ingest_segment("jackson", seg, frames)
    segs = list(range(n_segs))

    def run():
        return run_query(vs, cfg, "A", "jackson", segs, 0.8,
                         batch_segments=4)

    run()  # warm jit caches before any timed window
    reps = 3
    wall_off = wall_on = 0.0
    items_off = items_on = None
    obs.TRACER.clear()
    for _ in range(reps):  # interleaved so host drift hits both sides
        obs.enable(False)
        t0 = time.perf_counter()
        items_off = run().items
        wall_off += time.perf_counter() - t0
        obs.enable(True)
        t0 = time.perf_counter()
        items_on = run().items
        wall_on += time.perf_counter() - t0
    obs.enable(False)

    spans_per_query = len(obs.TRACER.spans()) / reps
    overhead_disabled_pct = (spans_per_query * ns_disabled * 1e-9
                             / (wall_off / reps)) * 100
    overhead_enabled_pct = (wall_on / wall_off - 1) * 100

    out = os.environ.get("OBS_TRACE_OUT") or f"{tmp_root}/trace.json"
    n_spans = obs.export_trace(out, process_names={obs.TRACER.pid: "bench"})
    with open(out) as f:
        doc = json.load(f)
    evs = [e for e in doc.get("traceEvents", []) if e.get("ph") == "X"]
    ids = {e["args"]["span"] for e in evs}
    trace_valid = bool(evs) and all(
        e["args"]["parent"] == "0" or e["args"]["parent"] in ids
        for e in evs)
    obs.TRACER.clear()

    row("obs_overhead", ns_disabled * 1e-3,
        f"mode=query;ns_disabled_span={ns_disabled:.0f};"
        f"spans_per_query={spans_per_query:.0f};"
        f"overhead_disabled_pct={overhead_disabled_pct:.3f};"
        f"overhead_enabled_pct={overhead_enabled_pct:.1f};"
        f"spans_exported={n_spans};"
        f"low_overhead={overhead_disabled_pct < 3.0};"
        f"trace_valid={trace_valid};"
        f"identical={items_on == items_off}")


def bench_telemetry_overhead(tmp_root="/tmp/repro_bench_telemetry"):
    """Beyond-paper: continuous telemetry cost + SLO accounting exactness
    (repro.obs.telemetry).

    Serve arm: the same concurrent workload with the telemetry sampler
    off vs on (interleaved windows), sampling at 20x the default rate —
    ``low_overhead`` claims the fsync'd sampling loop costs < 3% of query
    wall time (host-speed dependent, so in ``HOST_SPEED_BOOL_KEYS`` like
    obs_overhead's), and ``identical`` that sampling never perturbs items.

    Cluster arm, exactly gated: deadline hit/miss counters summed from the
    per-shard crash-safe logs' final frames must equal the router's stats
    rollup bit-exactly (``misses_exact``) — SLO accounting is counting,
    not estimation; and a worker SIGKILL'd mid-sampling must leave a log
    that reads back to the last fsync'd frame with a contiguous sequence
    and reopens writable on a clean frame boundary (``crash_safe``).
    ``TELEMETRY_OUT`` redirects the telemetry dir (CI uploads it as an
    artifact)."""
    import os
    import shutil

    from repro.cluster import ShardRouter
    from repro.launch.vserve import demo_config
    from repro.obs.telemetry import (TelemetryLog, TelemetrySampler,
                                     read_frames)
    from repro.serving import VStoreServer

    cfg = demo_config()
    n_segs = 2
    segs = list(range(n_segs))
    shutil.rmtree(tmp_root, ignore_errors=True)
    tdir = os.environ.get("TELEMETRY_OUT") or f"{tmp_root}/vtl"
    shutil.rmtree(tdir, ignore_errors=True)

    streams = ["jackson", "tucson"]  # crc32-hash to shards 1 and 0
    frames_by_key = {(s, g): generate_segment(s, g, SPEC)[0]
                     for s in streams for g in segs}
    vs = VideoStore(f"{tmp_root}/store", SPEC)
    vs.set_formats(cfg.storage_formats())
    for g in segs:
        vs.ingest_segment("jackson", g, frames_by_key[("jackson", g)])

    # -- serve arm: sampler off vs on, interleaved windows
    subs = [(q, "jackson", segs, a)
            for q in ("A", "B") for a in (0.8, 0.9)]
    spath = f"{tdir}/server.vtl"
    with VStoreServer(vs, cfg, workers=2) as srv:
        srv.run_batch(subs)  # warm jit + decoded caches
        probe = TelemetrySampler(srv.telemetry_body, TelemetryLog(spath),
                                 interval_s=9.0)
        t0 = time.perf_counter()
        for _ in range(50):
            probe.sample_now()
        us_sample = (time.perf_counter() - t0) / 50 * 1e6
        probe.stop(final=False)
        reps = 3
        wall_off = wall_on = 0.0
        items_off = items_on = None
        for _ in range(reps):  # interleaved so host drift hits both sides
            t0 = time.perf_counter()
            items_off = [r.items for r in srv.run_batch(subs)]
            wall_off += time.perf_counter() - t0
            # a fresh writable handle per window (stop() closes the log);
            # the reopen resumes the sequence in the same file
            sampler = TelemetrySampler(srv.telemetry_body,
                                       TelemetryLog(spath),
                                       interval_s=0.05).start()
            t0 = time.perf_counter()
            items_on = [r.items for r in srv.run_batch(subs)]
            wall_on += time.perf_counter() - t0
            sampler.stop(final=False)
    overhead_pct = (wall_on / wall_off - 1) * 100
    server_frames = read_frames(spath)
    row("telemetry_overhead", us_sample,
        f"mode=serve;n={len(subs)};segments={n_segs};"
        f"us_per_sample={us_sample:.0f};"
        f"overhead_pct={overhead_pct:.2f};"
        f"frames={len(server_frames)};"
        f"low_overhead={overhead_pct < 3.0};"
        f"identical={items_on == items_off}")

    # -- cluster arm: per-shard logs vs router rollup, SIGKILL mid-sample
    router = ShardRouter(f"{tmp_root}/cluster", cfg, 2, spec=SPEC,
                         opts={"workers": 1, "telemetry_dir": tdir,
                               "telemetry_interval_s": 0.05,
                               "slo_classes": {
                                   "interactive": {"slack_x": 50.0}}})
    try:
        router.start()
        router.attach_telemetry(interval_s=0.05)
        for (s, g), f in frames_by_key.items():
            router.ingest(s, g, f)
        csubs = [("A", s, segs, acc, {"slo_class": "interactive"})
                 for s in streams for acc in (0.8, 0.9)]
        # warm per-worker jit caches deadline-free so the SLO'd run below
        # measures the cascade, not compilation
        router.query_many([sub[:4] for sub in csubs])
        t0 = time.perf_counter()
        router.query_many(csubs)
        wall = time.perf_counter() - t0
        for s in streams:  # one impossible deadline per shard -> misses
            router.query("B", s, segs, 0.8, deadline_ms=0.001)
        st = router.stats()
        for h in router.hosts:  # force one durable post-workload sample
            h.call("sample_telemetry")
        shard_logs = [read_frames(
            os.path.join(tdir, f"shard-{h.idx:02d}.vtl"))
            for h in router.hosts]
        sums = {k: sum(fr[-1]["metrics"]["counters"].get(k, 0)
                       for fr in shard_logs)
                for k in ("deadline_hits", "deadline_misses")}
        misses_exact = (
            sums["deadline_hits"] == st["deadline_hits"] == len(csubs)
            and sums["deadline_misses"] == st["deadline_misses"]
            == len(streams))

        victim = router.host_of("jackson")
        vpath = os.path.join(tdir, f"shard-{victim.idx:02d}.vtl")
        victim.kill()  # SIGKILL with the 20Hz sampler loop mid-flight
        vframes = read_frames(vpath)
        relog = TelemetryLog(vpath)  # the respawned worker's reopen path
        crash_safe = (
            len(vframes) >= 1
            and [f["seq"] for f in vframes]
            == list(range(1, len(vframes) + 1))
            and relog.frames_recovered == len(vframes)
            and relog.append({"probe": True}) == len(vframes) + 1)
        relog.close()
        merged = router.telemetry_scrape()  # skips the dead shard
        survivors = merged["sources"]
    finally:
        router.close()
    cluster_frames = read_frames(os.path.join(tdir, "cluster.vtl"))
    row("telemetry_overhead", wall * 1e6,
        f"mode=cluster;shards=2;n={len(csubs)};"
        f"hits={st['deadline_hits']};misses={st['deadline_misses']};"
        f"cluster_frames={len(cluster_frames)};survivors={survivors};"
        f"misses_exact={misses_exact};crash_safe={crash_safe}")
