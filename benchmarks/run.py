"""Benchmark harness: one function per paper table/figure, plus the
roofline summary from the dry-run artifacts.

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` additionally
writes every row (plus per-bench wall times and errors) as a JSON file —
CI uploads these as ``BENCH_*.json`` artifacts so the perf trajectory
accumulates per commit.  ``--only a,b`` selects a subset of benches by
name (with or without the ``bench_`` prefix).  ``--check BASELINE.json``
turns the run into a regression gate: ratio metrics (speedups,
x-realtime) must stay within ``--check-factor`` of the committed baseline
and boolean correctness claims must hold (see benchmarks.check).
"""

from __future__ import annotations

import argparse
import json
import os
import time
import traceback


def bench_roofline():
    """§Roofline: three-term table from the compiled dry-run artifacts."""
    from repro.launch.roofline import load

    from .common import row
    path = "experiments/dryrun"
    if not os.path.isdir(path):
        row("roofline", 0.0, "status=missing;hint=run repro.launch.dryrun")
        return
    rows = load(path, "pod16x16")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        row("roofline", r["compute_s"] * 1e6 if r["compute_s"] else 0.0,
            f"arch={r['arch']};shape={r['shape']};"
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};dominant={r['dominant']};"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.4f}")


def main(argv=None) -> None:
    from . import common
    from . import paper_benches as B
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run (bench_ prefix "
                         "optional); default: all")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as a JSON file")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="regression-gate this run against a baseline "
                         "bench JSON (exit 1 on violations)")
    ap.add_argument("--check-factor", type=float, default=0.5,
                    help="minimum fraction of a baseline ratio metric the "
                         "current run must reach (default 0.5)")
    args = ap.parse_args(argv)

    benches = [
        B.bench_fig3_coding,
        B.bench_fig4_knobs,
        B.bench_fig6_retrieval_bottleneck,
        B.bench_table2_configuration,
        B.bench_fig11_end_to_end,
        B.bench_fig12_erosion,
        B.bench_table3_ingest_budget,
        B.bench_serve_concurrency,
        B.bench_batched_consumption,
        B.bench_cross_query_batching,
        B.bench_ingest_live,
        B.bench_ingest_soak,
        B.bench_predicate_pushdown,
        B.bench_cluster_scaling,
        B.bench_decode_path,
        B.bench_fig13_overhead,
        B.bench_obs_overhead,
        B.bench_telemetry_overhead,
        bench_roofline,
    ]
    if args.only:
        wanted = {w if w.startswith("bench_") else f"bench_{w}"
                  for w in args.only.split(",") if w}
        benches = [b for b in benches if b.__name__ in wanted]
        missing = wanted - {b.__name__ for b in benches}
        if missing:
            raise SystemExit(f"unknown benches: {sorted(missing)}")

    print("name,us_per_call,derived")
    for bench in benches:
        t0 = time.perf_counter()
        try:
            bench()
        except Exception as e:  # noqa: BLE001
            msg = f"ERROR={type(e).__name__}:{e}"
            common.ROWS.append({"name": bench.__name__, "us_per_call": 0.0,
                                "derived": msg})
            print(f"{bench.__name__},0.0,{msg}")
            traceback.print_exc()
        wall_us = (time.perf_counter() - t0) * 1e6
        common.ROWS.append({"name": f"_{bench.__name__}_wall",
                            "us_per_call": round(wall_us), "derived": "done"})
        print(f"_{bench.__name__}_wall,{wall_us:.0f},done")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": common.ROWS}, f, indent=1)
        print(f"wrote {len(common.ROWS)} rows to {args.json}")

    if args.check:
        from .check import check_rows
        with open(args.check) as f:
            baseline = json.load(f)["rows"]
        violations = check_rows(baseline, common.ROWS,
                                factor=args.check_factor)
        if violations:
            for v in violations:
                print(f"CHECK FAILED: {v}")
            raise SystemExit(1)
        print(f"check passed against {args.check} "
              f"(factor {args.check_factor})")


if __name__ == "__main__":
    main()
