"""Benchmark harness: one function per paper table/figure, plus the
roofline summary from the dry-run artifacts.

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import os
import time
import traceback


def bench_roofline():
    """§Roofline: three-term table from the compiled dry-run artifacts."""
    from repro.launch.roofline import load

    from .common import row
    path = "experiments/dryrun"
    if not os.path.isdir(path):
        row("roofline", 0.0, "status=missing;hint=run repro.launch.dryrun")
        return
    rows = load(path, "pod16x16")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        row("roofline", r["compute_s"] * 1e6 if r["compute_s"] else 0.0,
            f"arch={r['arch']};shape={r['shape']};"
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};dominant={r['dominant']};"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.4f}")


def main() -> None:
    from . import paper_benches as B
    benches = [
        B.bench_fig3_coding,
        B.bench_fig4_knobs,
        B.bench_fig6_retrieval_bottleneck,
        B.bench_table2_configuration,
        B.bench_fig11_end_to_end,
        B.bench_fig12_erosion,
        B.bench_table3_ingest_budget,
        B.bench_serve_concurrency,
        B.bench_fig13_overhead,
        bench_roofline,
    ]
    print("name,us_per_call,derived")
    for bench in benches:
        t0 = time.perf_counter()
        try:
            bench()
        except Exception as e:  # noqa: BLE001
            print(f"{bench.__name__},0.0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc()
        print(f"_{bench.__name__}_wall,"
              f"{(time.perf_counter() - t0) * 1e6:.0f},done")


if __name__ == "__main__":
    main()
