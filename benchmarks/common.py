"""Shared benchmark state: one profiler + derived configuration reused by
every table/figure benchmark (mirrors one VStore configuration process)."""

from __future__ import annotations

import functools
import time

from repro.core import Profiler, derive_config
from repro.core.knobs import IngestSpec

SPEC = IngestSpec()
ACCURACIES = (0.9, 0.8)       # reduced ladder keeps the suite CPU-affordable
N_SEGMENTS = 2


@functools.cache
def profiler() -> Profiler:
    return Profiler(SPEC, n_segments=N_SEGMENTS, repeats=1)


@functools.cache
def config():
    t0 = time.perf_counter()
    cfg = derive_config(profiler(), accuracies=ACCURACIES)
    cfg.derive_seconds = time.perf_counter() - t0
    return cfg


# every row() call also lands here so benchmarks.run can dump the whole
# suite as a JSON artifact (CI uploads BENCH_*.json for the perf trajectory)
ROWS: list[dict] = []


def row(name: str, us_per_call: float, derived: str):
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")
